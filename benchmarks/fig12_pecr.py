"""Paper Fig. 12: fused conv+ReLU+pool (PECR) vs unfused, per VGG-19 CP group.

Claim checked: fusing the pooling into the convolution (PECR, §V) beats the
separate conv -> ReLU -> pool pipeline because the conv result never leaves
fast memory. Three views of the fusion win:
  1. measured CPU wall time fused vs unfused (real, same-machine ratio),
  2. modeled HBM bytes (the paper's CPU<->GPU traffic argument mapped one
     level down the hierarchy, DESIGN.md §2.3),
  3. the paper's MAC-reduction metric for the conv inside the fusion.
"""
from __future__ import annotations

from functools import partial

import jax

from benchmarks._util import VGG19_CONVS, VGG19_SPARSITY, time_fn
from repro.core import conv_pool, synth_feature_map, window_stats
from repro.core.pecr import fused_traffic_bytes

# CP groups: the stage-final conv that feeds each pooling layer (half res)
CP_GROUPS = [(1, "CP_1"), (3, "CP_2"), (7, "CP_3"), (11, "CP_4"), (15, "CP_5")]


def main():
    for idx, label in CP_GROUPS:
        name, c, o, res = VGG19_CONVS[idx]
        sp = VGG19_SPARSITY[idx]
        x = synth_feature_map(jax.random.PRNGKey(idx), (c, res, res), sp)
        k = jax.random.normal(jax.random.PRNGKey(idx + 50), (o, c, 3, 3)) * 0.05
        fused = jax.jit(partial(conv_pool, c_s=1, p=2, impl="pecr"))
        unfused = jax.jit(partial(conv_pool, c_s=1, p=2, impl="unfused"))
        t_f = time_fn(fused, x, k, iters=3, warmup=1)
        t_u = time_fn(unfused, x, k, iters=3, warmup=1)
        st = window_stats(jax.device_get(x), 3, 3, 1)
        traffic = fused_traffic_bytes((c, res, res), o, 3, 3, dtype_bytes=2)
        print(f"fig12/{label},{t_f:.1f},"
              f"unfused_us={t_u:.1f} hbm_saved_frac={traffic['saved_frac']:.2f} "
              f"mac_red={st.mul_reduction:.2f} sparsity={sp:.2f}")


if __name__ == "__main__":
    main()
